"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 [hf:google/gemma-3 lineage].

5:1 local:global attention pattern (window 1024), decoupled head_dim=128,
qk-norm, pre+post RMSNorm around each sub-block (zero-centered scale),
GeGLU FFN, sqrt(d)-scaled tied embeddings, 128k-class context. The 262k
vocabulary makes the Logit-Computation group the dominant NonGEMM cost of
the loss — hence ``loss_chunk`` (sequence-chunked CE, paper §4.5 direction).
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    remat_policy="proj",
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    block_pattern=("local", "local", "local", "local", "local", "attn"),
    window_size=1024,
    pos_emb="rope",
    norm="rmsnorm",
    post_norm=True,
    zero_centered_norm=True,
    qk_norm=True,
    ffn="geglu",
    causal=True,
    tie_embeddings=True,
    scale_embeddings=True,
    loss_chunk=512,
    fsdp=True,
)
