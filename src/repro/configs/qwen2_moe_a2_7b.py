"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) moe_d_ff=1408,
60 routed experts top-4 + 4 shared experts [hf:Qwen/Qwen1.5-MoE-A2.7B].

Every layer is MoE. 60 experts do not divide the model axis (16), so the
sharding rules fall back to TP-within-expert (mlp dim, 1408/16=88) — see
sharding/__init__.py; DESIGN.md §Arch-applicability discusses the tradeoff.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    remat_policy="proj",
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    block_pattern=("attn",),
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    moe_d_ff=1408,
    capacity_factor=1.25,
    first_dense_layers=0,
    pos_emb="rope",
    norm="rmsnorm",
    ffn="swiglu",
    qkv_bias=True,
    causal=True,
    tie_embeddings=False,
    fsdp=True,
)
