"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (MHA, kv=32) d_ff=8192 vocab=2048 [arXiv:2306.05284].
Backbone only: the EnCodec frontend is a stub — ``input_specs()`` feeds
precomputed (B, S, d_model) frame embeddings (``input_mode="embeddings"``),
and the head predicts one codebook of 2048 audio tokens. MusicGen uses
sinusoidal positions, pre-LayerNorm blocks and GELU FFN (T5/Bart lineage).
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    remat_policy="proj",
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=("attn",),
    pos_emb="sinusoidal",
    norm="layernorm",
    ffn="gelu",
    ffn_bias=True,
    qkv_bias=False,
    causal=True,
    tie_embeddings=False,
    input_mode="embeddings",
    loss_chunk=0,
)
