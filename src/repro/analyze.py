"""``python -m repro.analyze`` — the nglint static-analysis entry point.

Thin shim over :mod:`repro.analysis.cli` so the command reads like the
other repro CLIs (``python -m repro.bench``, ``python -m
repro.bench.compare``).
"""

import sys

from repro.analysis.cli import main

if __name__ == "__main__":
    sys.exit(main())
