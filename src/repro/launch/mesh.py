"""Production meshes.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run pins the device count via XLA_FLAGS
*before* the first jax init; anything that forces an earlier init would
lock the real 1-device topology in).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds the 2-pod DCI axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1x1 mesh over the real local device (smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
