"""Production meshes.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run pins the device count via XLA_FLAGS
*before* the first jax init; anything that forces an earlier init would
lock the real 1-device topology in).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds the 2-pod DCI axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1x1 mesh over the real local device (smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_sim_mesh(data: int = 1, model: int = 1):
    """``(data, model)`` mesh over simulated host devices.

    The multi-device serving checks run TP/DP on one machine by asking XLA
    for virtual CPU devices. That only works if the device count was pinned
    BEFORE the first jax init, so this validates eagerly and names the knob
    instead of letting jax raise a shape error deep inside ``make_mesh``.
    """
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got ({data}, {model})")
    need = data * model
    have = jax.device_count()
    if need > have:
        raise RuntimeError(
            f"make_sim_mesh({data}, {model}) needs {need} devices but jax "
            f"sees {have}. Set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} in the "
            f"environment BEFORE the first jax import (the device count "
            f"locks at jax init; see scripts/sharded_serving_check.py).")
    import numpy as np
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=np.array(jax.devices()[:need]))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
