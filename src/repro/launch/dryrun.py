import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The roofline analyzer reads the post-SPMD-partitioning (pre-optimization)
# module: it has true dtypes (XLA:CPU's optimized module legalizes every
# bf16 buffer to f32 — 2x inflated and misleading for a TPU roofline),
# per-device shapes, and materialized collectives. Dumped per-process.
_DUMP_DIR = os.environ.get("REPRO_DUMP_DIR") or os.path.join(
    "/tmp", f"repro_xla_dump_{os.getpid()}")
os.environ["XLA_FLAGS"] += (
    f" --xla_dump_to={_DUMP_DIR} --xla_dump_hlo_pass_re=spmd-partitioning")

# Multi-pod dry-run (assignment deliverable e): lower + compile every
# (architecture x input shape) cell on the production meshes with
# ShapeDtypeStruct inputs — no allocation — and record memory_analysis /
# cost_analysis / trip-aware collective bytes for the roofline (deliverable
# g). The two lines above MUST precede any jax import: XLA locks the host
# platform device count at first init.
#
# Usage:
#   python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k
#   python -m repro.launch.dryrun --arch gemma3-27b --shape decode_32k --multi-pod
#   python -m repro.launch.dryrun --sweep [--multi-pod] [--jobs N]
#
# One cell per subprocess under --sweep: a pathological cell can neither
# corrupt nor block the rest (compile-time fault isolation mirrors the
# runtime fault-tolerance posture).

import argparse
import glob
import json
import subprocess
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding
from repro.configs import ARCH_IDS, get_config, reduced
from repro.core.hlo import KERNEL_REGION_MARKERS, analyze_partitioned
from repro.core.roofline import roofline_from_hlo
from repro.core.workload import Workload
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.specs import (abstract_state, input_specs, model_flops,
                                train_microbatches)
from repro.models.common import SHAPES, shape_applicable
from repro.optim import OptimizerConfig
from repro.runtime import TrainState, make_train_step
from repro.serving import make_prefill_step, make_serve_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _spec(mesh, *names):
    return NamedSharding(mesh, P(*names))


def _partitioned_text(compiled) -> str:
    """Read the post-SPMD-partitioning dump of the *step* module.

    Falls back to the optimized module if the dump is missing (e.g. a
    backend that doesn't honor the dump flags)."""
    pattern = os.path.join(_DUMP_DIR,
                           "*after_spmd-partitioning*.txt")
    candidates = [p for p in glob.glob(pattern)
                  if os.path.getsize(p) > 0]
    if not candidates:
        return compiled.as_text()
    # the step module is by far the largest dump in this process
    best = max(candidates, key=os.path.getsize)
    with open(best) as f:
        return f.read()


def _batch_spec(mesh, ndim: int, micro: bool):
    if micro:
        names = (None, ("pod", "data") if "pod" in mesh.axis_names
                 else "data") + (None,) * (ndim - 2)
    else:
        names = (("pod", "data") if "pod" in mesh.axis_names else "data",
                 ) + (None,) * (ndim - 1)
    return NamedSharding(mesh, P(*names))


def _token_batch_sharding(mesh, spec_tree, micro: bool):
    def one(s):
        dim0 = s.shape[1] if micro else s.shape[0]
        n_batch = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                n_batch *= dict(zip(mesh.axis_names,
                                    mesh.devices.shape))[ax]
        if dim0 % n_batch:
            return _spec(mesh)  # replicate (e.g. batch=1 long_500k)
        return _batch_spec(mesh, len(s.shape), micro)
    return jax.tree_util.tree_map(one, spec_tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               use_reduced: bool = False, opt_overrides: dict = None,
               compile_only: bool = False) -> dict:
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    shape = SHAPES[shape_name]
    if use_reduced:
        shape = shape.__class__(shape.name, seq_len=256,
                                global_batch=max(shape.global_batch // 8, 8),
                                kind=shape.kind)
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": ("long_500k needs sub-quadratic attention"
                            if shape.name == "long_500k"
                            else "no decode step for encoder-only")}
    if shape.kind == "prefill":
        # Megatron-SP on the prefill residual stream: a pure win for the
        # forward-only serving path (§Perf iteration 3); training keeps
        # plain TP (iterations 4-5 refuted SP under the remat backward).
        cfg = cfg.replace(seq_shard=True)
    if opt_overrides:
        cfg = cfg.replace(**opt_overrides)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data = sizes.get("data", 1) * sizes.get("pod", 1)

    t0 = time.time()
    if shape.kind == "train":
        n_micro = train_microbatches(cfg, shape, n_data)
        specs = input_specs(cfg, shape, mesh, num_microbatches=n_micro)
        state = abstract_state(cfg)
        state_sh = TrainState(
            sharding.param_sharding(state.params, mesh, cfg.fsdp),
            type(state.opt)(
                step=_spec(mesh),
                mu=sharding.param_sharding(state.opt.mu, mesh, cfg.fsdp),
                nu=sharding.param_sharding(state.opt.nu, mesh, cfg.fsdp),
                err=None))
        batch_sh = _token_batch_sharding(mesh, specs["batch"], n_micro > 1)
        step = make_train_step(cfg, OptimizerConfig(), mesh,
                               num_microbatches=n_micro)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
        lowered = jitted.lower(state, specs["batch"])
        extra = {"num_microbatches": n_micro}
    elif shape.kind == "prefill":
        specs = input_specs(cfg, shape, mesh)
        params = abstract_state(cfg).params
        params_sh = sharding.param_sharding(params, mesh, cfg.fsdp)
        tok_sh = _token_batch_sharding(mesh, specs["tokens"], False)
        step = make_prefill_step(cfg, max_len=shape.seq_len, mesh=mesh)
        jitted = jax.jit(step, in_shardings=(params_sh, tok_sh))
        lowered = jitted.lower(params, specs["tokens"])
        extra = {}
    else:  # decode: serve_step with the engine's per-slot pos vector (B,)
        specs = input_specs(cfg, shape, mesh)
        params = abstract_state(cfg).params
        params_sh = sharding.param_sharding(params, mesh, cfg.fsdp)
        cache_sh = sharding.cache_sharding(specs["caches"], mesh)
        tok_sh = _token_batch_sharding(mesh, specs["token"], False)
        step = make_serve_step(cfg, mesh, greedy=True)
        jitted = jax.jit(step,
                         in_shardings=(params_sh, tok_sh, _spec(mesh),
                                       cache_sh, _spec(mesh)),
                         donate_argnums=(3,))
        lowered = jitted.lower(params, specs["token"], specs["pos"],
                               specs["caches"], specs["key"])
        extra = {}
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                mem[f] = int(v)
        print("memory_analysis:", mem)
    except Exception as e:  # backend without memory analysis
        mem = {"error": str(e)}
    cost = {}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "optimal_seconds",
                 "utilization operand 0 {}", "bytes accessed output {}")}
        print("cost_analysis:", {k: cost[k] for k in list(cost)[:4]})
    except Exception as e:
        cost = {"error": str(e)}

    text = _partitioned_text(compiled)
    mf = model_flops(cfg, shape)
    # two memory models of the same lowered program: XLA-fusion-only (the
    # paper-faithful baseline) and Pallas-kernel regions (the deployed
    # system, kernels/ replacing the tagged NonGEMM hot spots)
    hlo_xla = analyze_partitioned(text)
    hlo = analyze_partitioned(text, kernel_regions=KERNEL_REGION_MARKERS)
    terms = roofline_from_hlo(hlo, chips, model_flops=mf)
    terms_xla = roofline_from_hlo(hlo_xla, chips, model_flops=mf)

    # the cell as a declarative Workload, profiled through the unified
    # compiled backend over the already-partitioned module: the paper's
    # GEMM/NonGEMM split of every production cell, for free
    workload = Workload(name=f"{arch}/{shape_name}", arch=arch,
                        phase=shape.kind, batch=shape.global_batch,
                        seq=shape.seq_len, dtype=cfg.dtype)
    prof = workload.profile("compiled:tpu_v5e", hlo_text=text)

    bytes_per_device = sum(v for k, v in mem.items()
                           if isinstance(v, int) and k != "alias_size_in_bytes"
                           and k != "generated_code_size_in_bytes")
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "reduced": use_reduced,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        "bytes_per_device": bytes_per_device,
        "cost_analysis": cost,
        "hlo": hlo.to_dict(),
        "hlo_xla_only": hlo_xla.to_dict(),
        "model_flops": mf,
        "roofline": terms.to_dict(),
        "roofline_xla_only": terms_xla.to_dict(),
        "workload": workload.describe(),
        "gemm_nongemm": {
            "gemm_frac": prof.split["gemm_frac"],
            "nongemm_frac": prof.split["nongemm_frac"],
            "mode": prof.mode,
        },
        **extra,
    }
    return result


def cell_path(arch: str, shape_name: str, multi_pod: bool,
              out_dir: str = None) -> str:
    d = os.path.abspath(out_dir or RESULTS_DIR)
    d = os.path.join(d, "multi" if multi_pod else "single")
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape_name}.json")


def run_one(args) -> int:
    try:
        res = lower_cell(args.arch, args.shape, args.multi_pod,
                         use_reduced=args.reduced,
                         opt_overrides=json.loads(args.overrides)
                         if args.overrides else None)
    except Exception:
        res = {"arch": args.arch, "shape": args.shape,
               "mesh": "multi" if args.multi_pod else "single",
               "error": traceback.format_exc()}
    path = cell_path(args.arch, args.shape, args.multi_pod, args.out)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    if "error" in res:
        print(f"FAIL {args.arch} x {args.shape}:\n{res['error']}",
              file=sys.stderr)
        return 1
    if "skipped" in res:
        print(f"SKIP {args.arch} x {args.shape}: {res['skipped']}")
        return 0
    r = res["roofline"]
    print(f"OK {args.arch} x {args.shape} [{res['mesh']}] "
          f"compile {res['compile_s']}s  "
          f"compute {r['compute_s']:.4f}s memory {r['memory_s']:.4f}s "
          f"collective {r['collective_s']:.4f}s -> {r['dominant']}-bound  "
          f"useful_ratio {r['useful_ratio']:.2f} mfu {r['mfu']:.3f}")
    return 0


def run_sweep(args) -> int:
    cells = [(a, s) for a in (args.archs or ARCH_IDS) for s in SHAPES]
    procs = []
    failures = 0
    max_jobs = max(args.jobs, 1)

    def reap(block: bool):
        nonlocal failures
        for p, (a, s) in list(procs):
            if p.poll() is not None or block:
                rc = p.wait()
                failures += int(rc != 0)
                procs.remove((p, (a, s)))

    for a, s in cells:
        if args.skip_done and os.path.exists(
                cell_path(a, s, args.multi_pod, args.out)):
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", a, "--shape", s]
        if args.multi_pod:
            cmd.append("--multi-pod")
        if args.reduced:
            cmd.append("--reduced")
        if args.out:
            cmd += ["--out", args.out]
        while len(procs) >= max_jobs:
            reap(block=False)
            time.sleep(2)
        print(f"[sweep] launch {a} x {s}", flush=True)
        procs.append((subprocess.Popen(cmd), (a, s)))
    while procs:
        reap(block=False)
        time.sleep(2)
    print(f"[sweep] done; {failures} failures")
    return int(failures > 0)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny config self-test (CI)")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--archs", nargs="*")
    ap.add_argument("--out", default=None)
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of ModelConfig overrides (perf sweeps)")
    args = ap.parse_args()
    if args.sweep:
        return run_sweep(args)
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --sweep)")
    return run_one(args)


if __name__ == "__main__":
    sys.exit(main())
