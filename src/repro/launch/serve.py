"""Serving driver: continuous-batching Engine over one shared KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --reduced --requests 12 --max-new 16
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_lm
from repro.serving import Engine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} takes frame embeddings; the token "
                         "serving driver does not apply (see DESIGN.md)")
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    eng = Engine(cfg, params, max_batch=args.max_batch, max_len=args.max_len)
    rng = np.random.RandomState(args.seed)
    for i in range(args.requests):
        plen = int(rng.randint(4, 24))
        prompt = rng.randint(1, cfg.vocab_size, size=plen).tolist()
        eng.add_request(prompt, max_new_tokens=args.max_new)
    done = eng.run()
    for r in done[:4]:
        print(f"req {r.uid}: prompt[{len(r.prompt)}] "
              f"ttft={r.ttft_s*1e3:.1f}ms -> {r.output}")
    s = eng.stats
    print(f"requests={len(done)} prefill={s.prefill_s:.2f}s "
          f"decode={s.decode_s:.2f}s decode_tok/s={s.decode_tok_per_s:.1f} "
          f"mean_ttft={s.mean_ttft_s*1e3:.1f}ms "
          f"mean_queue_wait={s.mean_queue_wait_s*1e3:.1f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
