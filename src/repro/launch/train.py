"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
        --steps 200 --seq 256 --batch 8 --reduced --ckpt /tmp/ckpt

On a real cluster this binary runs once per host (jax.distributed
initializes from the cluster env); in this container it drives the reduced
configs on the local device. ``--resume auto`` restores the latest
committed checkpoint — combined with the step-indexed data pipeline the
restart is bit-exact.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduced
from repro.data import DataConfig
from repro.models import init_lm
from repro.optim import OptimizerConfig
from repro.runtime import Trainer


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", choices=["auto", "never"], default="auto")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced config (CPU-scale)")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0)
    opt_cfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                              total_steps=args.steps,
                              compress_grads=args.compress_grads)

    trainer = Trainer(
        cfg, opt_cfg, data_cfg,
        init_params_fn=lambda: init_lm(jax.random.PRNGKey(args.seed), cfg),
        ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
        num_microbatches=args.micro)
    trainer.install_preemption_handler()
    if args.resume == "auto":
        trainer.try_resume()
    out = trainer.train(args.steps)
    print(f"done: step={out['step']} stragglers={out['stragglers']} "
          f"preempted={out['preempted']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
