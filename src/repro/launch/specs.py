"""ShapeDtypeStruct input stand-ins + sharding trees for every dry-run cell.

``input_specs(cfg, shape)`` returns abstract inputs for the step the shape
lowers (train -> train_step batch; prefill -> token batch; decode -> one
token + the seq_len-deep cache). Nothing here allocates device memory.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.roofline import attention_flops
from repro.models import init_lm, init_lm_cache
from repro.models.common import ModelConfig, ShapeSpec
from repro.optim import OptimizerConfig, init_opt_state
from repro.runtime import TrainState, pick_microbatches


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


def abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: init_lm(key, cfg))


def abstract_state(cfg: ModelConfig, opt_cfg: Optional[OptimizerConfig] = None):
    opt_cfg = opt_cfg or OptimizerConfig()
    params = abstract_params(cfg)
    opt = jax.eval_shape(lambda: init_opt_state(
        jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), params), opt_cfg))
    return TrainState(params, opt)


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_lm_cache(cfg, batch, max_len))


def train_microbatches(cfg: ModelConfig, shape: ShapeSpec, n_data: int,
                       budget_bytes: float = 4e9) -> int:
    per_dev = max(shape.global_batch // n_data, 1)
    return pick_microbatches(cfg, shape.seq_len, per_dev, budget_bytes)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh=None,
                num_microbatches: int = 1) -> dict:
    """Abstract model inputs for this (arch x shape) cell.

    train:   {"inputs": (B, S)[xV], "labels": (B, S)} — microbatched to
             (n_micro, B/n_micro, S) when num_microbatches > 1
    prefill: {"tokens": (B, S)}
    decode:  {"token": (B,), "pos": (B,), "caches": <seq_len-deep cache>}
             (``pos`` is the continuous-batching engine's per-slot position
             vector — the shape the production serve_step actually runs)
    """
    b, s = shape.global_batch, shape.seq_len
    tok_dt = jnp.int32

    def tok_spec(bsz, slen):
        if cfg.input_mode == "tokens":
            return sds((bsz, slen), tok_dt)
        return sds((bsz, slen, cfg.d_model), cfg.dtype)

    if shape.kind == "train":
        mb = b // num_microbatches
        if num_microbatches > 1:
            inputs = (sds((num_microbatches, mb, s), tok_dt)
                      if cfg.input_mode == "tokens"
                      else sds((num_microbatches, mb, s, cfg.d_model),
                               cfg.dtype))
            labels = sds((num_microbatches, mb, s), tok_dt)
        else:
            inputs = tok_spec(b, s)
            labels = sds((b, s), tok_dt)
        return {"batch": {"inputs": inputs, "labels": labels}}

    if shape.kind == "prefill":
        return {"tokens": tok_spec(b, s)}

    # decode: one new token per slot against a seq_len-deep cache
    token = (sds((b,), tok_dt) if cfg.input_mode == "tokens"
             else sds((b, cfg.d_model), cfg.dtype))
    caches = abstract_caches(cfg, b, s)
    return {"token": token, "pos": sds((b,), jnp.int32), "caches": caches,
            "key": sds((2,), jnp.uint32)}


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference) + attention terms."""
    n_active = cfg.n_params_active()
    b, s = shape.global_batch, shape.seq_len
    kinds = cfg.layer_kinds()
    attn = 0.0
    for kind in kinds:
        if kind not in ("attn", "local"):
            continue
        window = cfg.window_size if kind == "local" else None
        hd = (cfg.qk_nope_dim + cfg.qk_rope_dim) if cfg.mla \
            else cfg.resolved_head_dim
        if shape.kind == "train":
            attn += attention_flops(b, s, cfg.n_heads, hd,
                                    causal=cfg.causal, window=window,
                                    train=True)
        elif shape.kind == "prefill":
            attn += attention_flops(b, s, cfg.n_heads, hd,
                                    causal=cfg.causal, window=window,
                                    train=False)
        else:  # decode: one token attends to the full cache
            t = min(window, s) if window else s
            attn += 2 * 2.0 * b * cfg.n_heads * hd * t
    if shape.kind == "train":
        return 6.0 * n_active * (b * s) + attn
    if shape.kind == "prefill":
        return 2.0 * n_active * (b * s) + attn
    return 2.0 * n_active * b + attn
